"""Shared fault-injection test harness for the scheduler/cluster/multihost
stack (ISSUE 4 satellite).

Conventions (also documented in ROADMAP.md):

  * ``ScriptedExecutor`` — a ``SliceExecutor.run_segment`` stand-in that
    returns *fabricated* wall times (``slow`` x the analytic prior, or an
    explicit ``durations`` callable). No jax, no checkpoints: pure
    scheduling. Inject faults with ``crash_on(call_idx, seg) -> bool``
    (raises :class:`InjectedCrash`) and latency with ``delay`` seconds
    (real, or instant via a :class:`FakeClock`).
  * ``FakeRunner`` — wraps a ScriptedExecutor + an N-unit token
    ``DevicePool`` so engine code paths (``_run_adaptive``,
    ``ClusterRunner``) run deterministically inline.
  * ``NoPool`` — placeholder checkpoint pool for fakes that never touch it.
  * ``FakeClock`` — manual virtual time; pass as ``clock=`` so injected
    delays advance it instead of sleeping.
  * ``FakeHostTransport`` — an in-memory stand-in for the multihost
    :class:`~repro.cluster.multihost.ProcessTransport`: a scripted worker
    thread that speaks the real wire protocol (every message round-trips
    through ``pickle``), fabricates ``done`` records, honors the
    checkpoint-write contract for preempted segments, and supports
    ``kill()`` plus scripted mid-segment death (``die_on``) — so
    dispatcher-level fault paths are testable in milliseconds, without
    subprocesses or jax.

Keep fakes here, not in individual test modules: every new scheduler or
dispatch feature gets its fault cases from one toolbox.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Tuple

from repro.cluster.pool import DevicePool
from repro.sched.engine import JobRecord
from repro.sched.planner import ScheduledJob


class InjectedCrash(RuntimeError):
    """Raised by ScriptedExecutor when a scripted crash triggers."""


class FakeClock:
    """Manually advanced virtual time (thread-safe)."""

    def __init__(self, start: float = 0.0):
        self._t = start
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> None:
        with self._lock:
            self._t += dt

    def sleep(self, dt: float) -> None:
        self.advance(dt)


class NoPool:
    """Placeholder checkpoint pool (fakes never touch it)."""


class ScriptedExecutor:
    """``run_segment`` stand-in with scripted durations + fault injection.

    Wall time per step is ``slow * prior.iter_time(sel, degree, seq)``
    unless ``durations(seg, sel, seq)`` is given. Every call is recorded on
    ``.calls`` as ``(config_ids, units, run_steps)``.
    """

    def __init__(
        self,
        prior,
        slow: float = 1.0,
        *,
        durations: Optional[Callable] = None,
        crash_on: Optional[Callable] = None,
        delay: float = 0.0,
        clock: Optional[FakeClock] = None,
    ):
        self.prior = prior
        self.slow = slow
        self.durations = durations
        self.crash_on = crash_on
        self.delay = delay
        self.clock = clock
        self.calls: List[Tuple[Tuple[int, ...], Tuple[int, ...], int]] = []
        self.impls: List = []  # kernel impl received per segment

    def pack_template(self, cfg, configs, seed: int = 0):
        return None  # ClusterRunner pre-warm hook: nothing to warm

    def run_segment(self, seg, configs_by_cid, total_steps, cfg, base, *,
                    seq, pool, data_iter_fn, seed, slice_,
                    impl=None, remat=None, base_dtype=None):
        idx = len(self.calls)
        sel = [configs_by_cid[c] for c in seg.config_ids]
        self.calls.append((seg.config_ids, seg.units, seg.run_steps))
        self.impls.append(impl)
        if self.crash_on is not None and self.crash_on(idx, seg):
            raise InjectedCrash(f"injected crash at call {idx}")
        if self.delay:
            (self.clock.sleep if self.clock else time.sleep)(self.delay)
        if self.durations is not None:
            per_step = self.durations(seg, sel, seq)
        else:
            per_step = self.slow * self.prior.iter_time(sel, seg.degree, seq)
        return JobRecord(
            ScheduledJob(seg.config_ids, seg.degree, seg.start, seg.end),
            per_step * seg.run_steps,
        )


def fake_pool(n: int) -> DevicePool:
    """N-unit DevicePool over plain tokens (accounting needs no jax devs)."""
    return DevicePool(devices=[f"fake{i}" for i in range(n)])


class FakeRunner:
    """A full :class:`~repro.cluster.api.Runner` over fakes: ScriptedExecutor
    + token pool, inline (non-concurrent) execution — fully deterministic
    engine tests. ``run`` delegates to a real ``ClusterRunner`` on the fake
    pool, so the dispatch/lease/record semantics are the production ones."""

    def __init__(self, executor, n_units: int):
        self.executor = executor
        self.device_pool = fake_pool(n_units)
        self.concurrent = False

    def run(self, *args, **kwargs):
        from repro.cluster.runner import ClusterRunner

        inner = ClusterRunner(
            self.executor, self.device_pool, concurrent=False
        )
        return inner.run(*args, **kwargs)


# ---------------------------------------------------------------------------
# Multihost: in-memory transport with scripted worker + death/hang injection
# ---------------------------------------------------------------------------
#
# FakeHostTransport/DictPool moved to ``repro.cluster.testing`` so benchmarks
# (bench_elastic's emulated heterogeneous fleet) share the exact fake the
# test-suite trusts; re-exported here so test imports are unchanged.

from repro.cluster.testing import DictPool, FakeHostTransport  # noqa: E402,F401

"""Shared fault-injection test harness for the scheduler/cluster/multihost
stack (ISSUE 4 satellite).

Conventions (also documented in ROADMAP.md):

  * ``ScriptedExecutor`` — a ``SliceExecutor.run_segment`` stand-in that
    returns *fabricated* wall times (``slow`` x the analytic prior, or an
    explicit ``durations`` callable). No jax, no checkpoints: pure
    scheduling. Inject faults with ``crash_on(call_idx, seg) -> bool``
    (raises :class:`InjectedCrash`) and latency with ``delay`` seconds
    (real, or instant via a :class:`FakeClock`).
  * ``FakeRunner`` — wraps a ScriptedExecutor + an N-unit token
    ``DevicePool`` so engine code paths (``_run_adaptive``,
    ``ClusterRunner``) run deterministically inline.
  * ``NoPool`` — placeholder checkpoint pool for fakes that never touch it.
  * ``FakeClock`` — manual virtual time; pass as ``clock=`` so injected
    delays advance it instead of sleeping.
  * ``FakeHostTransport`` — an in-memory stand-in for the multihost
    :class:`~repro.cluster.multihost.ProcessTransport`: a scripted worker
    thread that speaks the real wire protocol (every message round-trips
    through ``pickle``), fabricates ``done`` records, honors the
    checkpoint-write contract for preempted segments, and supports
    ``kill()`` plus scripted mid-segment death (``die_on``) — so
    dispatcher-level fault paths are testable in milliseconds, without
    subprocesses or jax.

Keep fakes here, not in individual test modules: every new scheduler or
dispatch feature gets its fault cases from one toolbox.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.pool import DevicePool
from repro.sched.engine import JobRecord
from repro.sched.planner import ScheduledJob


class InjectedCrash(RuntimeError):
    """Raised by ScriptedExecutor when a scripted crash triggers."""


class FakeClock:
    """Manually advanced virtual time (thread-safe)."""

    def __init__(self, start: float = 0.0):
        self._t = start
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> None:
        with self._lock:
            self._t += dt

    def sleep(self, dt: float) -> None:
        self.advance(dt)


class NoPool:
    """Placeholder checkpoint pool (fakes never touch it)."""


class ScriptedExecutor:
    """``run_segment`` stand-in with scripted durations + fault injection.

    Wall time per step is ``slow * prior.iter_time(sel, degree, seq)``
    unless ``durations(seg, sel, seq)`` is given. Every call is recorded on
    ``.calls`` as ``(config_ids, units, run_steps)``.
    """

    def __init__(
        self,
        prior,
        slow: float = 1.0,
        *,
        durations: Optional[Callable] = None,
        crash_on: Optional[Callable] = None,
        delay: float = 0.0,
        clock: Optional[FakeClock] = None,
    ):
        self.prior = prior
        self.slow = slow
        self.durations = durations
        self.crash_on = crash_on
        self.delay = delay
        self.clock = clock
        self.calls: List[Tuple[Tuple[int, ...], Tuple[int, ...], int]] = []
        self.impls: List = []  # kernel impl received per segment

    def pack_template(self, cfg, configs, seed: int = 0):
        return None  # ClusterRunner pre-warm hook: nothing to warm

    def run_segment(self, seg, configs_by_cid, total_steps, cfg, base, *,
                    seq, pool, data_iter_fn, seed, slice_,
                    impl=None, remat=None, base_dtype=None):
        idx = len(self.calls)
        sel = [configs_by_cid[c] for c in seg.config_ids]
        self.calls.append((seg.config_ids, seg.units, seg.run_steps))
        self.impls.append(impl)
        if self.crash_on is not None and self.crash_on(idx, seg):
            raise InjectedCrash(f"injected crash at call {idx}")
        if self.delay:
            (self.clock.sleep if self.clock else time.sleep)(self.delay)
        if self.durations is not None:
            per_step = self.durations(seg, sel, seq)
        else:
            per_step = self.slow * self.prior.iter_time(sel, seg.degree, seq)
        return JobRecord(
            ScheduledJob(seg.config_ids, seg.degree, seg.start, seg.end),
            per_step * seg.run_steps,
        )


def fake_pool(n: int) -> DevicePool:
    """N-unit DevicePool over plain tokens (accounting needs no jax devs)."""
    return DevicePool(devices=[f"fake{i}" for i in range(n)])


class FakeRunner:
    """A full :class:`~repro.cluster.api.Runner` over fakes: ScriptedExecutor
    + token pool, inline (non-concurrent) execution — fully deterministic
    engine tests. ``run`` delegates to a real ``ClusterRunner`` on the fake
    pool, so the dispatch/lease/record semantics are the production ones."""

    def __init__(self, executor, n_units: int):
        self.executor = executor
        self.device_pool = fake_pool(n_units)
        self.concurrent = False

    def run(self, *args, **kwargs):
        from repro.cluster.runner import ClusterRunner

        inner = ClusterRunner(
            self.executor, self.device_pool, concurrent=False
        )
        return inner.run(*args, **kwargs)


# ---------------------------------------------------------------------------
# Multihost: in-memory transport with scripted worker + death injection
# ---------------------------------------------------------------------------


class FakeHostTransport:
    """In-memory ``ProcessTransport`` stand-in speaking the real protocol.

    A worker thread answers ``init``/``run``/``stop``; every message is
    forced through ``pickle`` both ways, so anything that would not survive
    the real process boundary fails here too. Fabricated results honor the
    executor's checkpoint contract: ``done_ids`` produce ``adapter`` writes,
    unfinished resumable adapters produce ``state`` writes with exact
    ``steps_done`` accounting, and resumed cids *must* have had their state
    shipped in ``states`` (asserted — recorded on ``.resumed``).

    Death injection: ``die_on(run_idx, payload) -> bool`` makes the worker
    drop the request and go silent (exactly what SIGKILL looks like from the
    dispatcher); ``kill()`` does the same from the outside.

    The kernel policy shipped with each run request is recorded on
    ``.policies`` (a ``KernelPolicy`` per run, in arrival order).

    Trace context: every ``run`` payload's ``trace`` field (a
    :class:`~repro.obs.TraceCtx` or None) is recorded on ``.trace_ctxs``;
    when present, the fabricated done reply carries worker-shaped ``spans``
    + ``span_t0`` exactly like a real traced worker, so dispatcher-side
    stitching (``Tracer.ingest``) is testable without subprocesses.
    """

    def __init__(
        self,
        host_id: int,
        n_devices: int,
        *,
        die_on: Optional[Callable] = None,
        iter_scale: float = 1e-3,
        on_run: Optional[Callable] = None,
    ):
        self.host_id = host_id
        self.n_devices = n_devices
        self.die_on = die_on
        self.iter_scale = iter_scale
        self.on_run = on_run
        self.runs: List[dict] = []
        self.policies: List = []  # KernelPolicy per run request
        self.trace_ctxs: List = []  # TraceCtx | None per run request
        self.resumed: List[Tuple[int, str]] = []
        self.error: Optional[BaseException] = None
        self._in: "queue.Queue" = queue.Queue()
        self._out: "queue.Queue" = queue.Queue()
        self._alive = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # -- transport interface -------------------------------------------------

    def send(self, msg) -> None:
        self._in.put(pickle.dumps(msg))

    def recv(self, timeout: Optional[float] = None):
        return pickle.loads(self._out.get(timeout=timeout))

    def alive(self) -> bool:
        return self._alive

    def kill(self) -> None:
        self._alive = False
        self._in.put(None)  # wake the loop so it exits

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # -- scripted worker -----------------------------------------------------

    def _reply(self, msg) -> None:
        self._out.put(pickle.dumps(msg))

    def _loop(self) -> None:
        # any exit — scripted death, stop, or an unexpected exception (e.g.
        # a contract assert below) — must leave alive()==False, or the
        # dispatcher pump would wait forever instead of failing crisply
        try:
            self._run_loop()
        except BaseException as e:  # noqa: BLE001 — surfaced via .error
            self.error = e
            raise
        finally:
            self._alive = False

    def _run_loop(self) -> None:
        self._reply(("ready", {"host": self.host_id,
                               "devices": self.n_devices}))
        state: Dict = {}
        while True:
            raw = self._in.get()
            if raw is None or not self._alive:
                return
            kind, payload = pickle.loads(raw)
            if kind == "stop":
                self._alive = False
                return
            if kind == "init":
                state = payload
                continue
            assert kind == "run", kind
            from repro.cluster.multihost import (
                CheckpointWrite,
                KernelPolicy,
                RecordMsg,
            )

            run_idx = len(self.runs)
            self.runs.append(payload)
            self.policies.append(payload.get("policy") or KernelPolicy())
            self.trace_ctxs.append(payload.get("trace"))
            if self.die_on is not None and self.die_on(run_idx, payload):
                self._alive = False  # died mid-segment: no reply, ever
                return
            if self.on_run is not None:
                self.on_run(run_idx, payload)
            seg = payload["seg"]  # SegmentMsg
            cids = tuple(seg.config_ids)
            total = state["total_steps"]
            for cid, st0 in zip(cids, seg.start_steps):
                if st0 > 0:
                    aid = f"{cid:04d}"
                    assert aid in payload["states"], (
                        f"resume of cid {cid} without shipped state"
                    )
                    tree, meta = payload["states"][aid]
                    assert int(meta["steps_done"]) == st0, (meta, st0)
                    self.resumed.append((run_idx, aid))
            writes = []
            if payload["has_pool"]:
                done = set(seg.done_ids)
                for slot, (cid, st0) in enumerate(
                    zip(cids, seg.start_steps)
                ):
                    if cid in done:
                        writes.append(
                            CheckpointWrite(
                                "adapter", f"adapter_{cid:04d}",
                                {"w": np.float32(cid)},
                                {"final_loss": 1.0,
                                 "total_steps": int(total[cid])})
                        )
                    else:
                        writes.append(
                            CheckpointWrite(
                                "state", f"{cid:04d}",
                                {"w": np.float32(cid),
                                 "m": np.float32(0), "v": np.float32(0)},
                                {"steps_done": int(st0 + seg.run_steps),
                                 "total_steps": int(total[cid])})
                        )
            wall = self.iter_scale * seg.run_steps
            done = {
                "req": payload["req"],
                "host": self.host_id,
                "record": RecordMsg(
                    config_ids=cids,
                    degree=seg.degree,
                    start=seg.start,
                    end=seg.end,
                    wall_seconds=wall,
                    losses=np.full(len(cids), 1.0, np.float32),
                ),
                "writes": writes,
            }
            if payload.get("trace") is not None:
                # worker-shaped span tree on the worker's own clock (t0=0):
                # a host root + one executor child, as Span.to_dict() dicts
                done["spans"] = [
                    {"name": f"host{self.host_id}.segment", "cat": "host",
                     "track": "", "span_id": 1, "parent_id": None,
                     "root_id": 1, "start": 0.0, "end": wall,
                     "args": {"job_id": seg.job_id, "fake": True}},
                    {"name": "executor.segment", "cat": "executor",
                     "track": "unit0", "span_id": 2, "parent_id": 1,
                     "root_id": 1, "start": 0.0, "end": wall,
                     "args": {"job_id": seg.job_id}},
                ]
                done["span_t0"] = 0.0
            self._reply(("done", done))


class DictPool:
    """Minimal in-memory CheckpointPool double for dispatcher-level tests:
    implements exactly the four methods the segment protocol uses."""

    def __init__(self):
        self.adapters: Dict[str, Tuple[dict, dict]] = {}
        self.states: Dict[str, Tuple[dict, dict]] = {}

    def has_adapter_state(self, aid: str) -> bool:
        return aid in self.states

    def load_adapter_state(self, aid: str):
        return self.states[aid]

    def save_adapter_state(self, aid: str, tree, meta: dict):
        self.states[aid] = (tree, dict(meta))

    def save_adapter(self, aid: str, tree, meta: dict):
        self.adapters[aid] = (tree, dict(meta))

"""Multi-host dispatch tier (ISSUE 4 tentpole).

Three layers, cheapest first:

  * wire-protocol round-trips — segment/checkpoint serialization must be
    bit-exact through a real pickle boundary;
  * dispatcher semantics over the in-memory ``FakeHostTransport`` from
    tests/harness.py — (host, unit) addressing, checkpoint traffic,
    worker-death re-queue through the preempt path — in milliseconds;
  * real-subprocess runs (marked ``slow``; CI's multihost matrix entry runs
    them explicitly): a 2-host x 4-device plan is loss-bit-identical to the
    1-host 8-device run, and a SIGKILLed worker mid-segment recovers with
    exact step budgets.
"""
import pickle
import threading
import time

import numpy as np
import pytest
from harness import DictPool, FakeHostTransport

from repro.cluster.multihost import (
    HostDispatcher,
    MemoryPool,
    WorkerDied,
    decode_record,
    decode_segment,
    encode_record,
    encode_segment,
    encode_tree,
)
from repro.configs.base import LoraConfig, get_config, reduced
from repro.sched.engine import JobRecord, JobSegment
from repro.sched.planner import ScheduledJob

SEQ = 16


def _cfg(rank=8, alpha=8.0, lr=1e-3, bs=1):
    return LoraConfig(
        rank=rank, alpha=alpha, learning_rate=lr, batch_size=bs, seq_len=SEQ
    )


def _seg(job_id=0, cids=(0,), degree=1, start_steps=None, run_steps=3,
         done=None, preempted=False, units=None, start=0.0, end=1.0):
    cids = tuple(cids)
    return JobSegment(
        job_id=job_id,
        config_ids=cids,
        degree=degree,
        start=start,
        end=end,
        start_steps=tuple(start_steps or (0,) * len(cids)),
        run_steps=run_steps,
        done_ids=tuple(cids if done is None else done),
        preempted=preempted,
        units=tuple(units if units is not None else range(degree)),
    )


# ---------------------------------------------------------------------------
# Protocol round-trips (bit-exactness through a real pickle boundary)
# ---------------------------------------------------------------------------


def _wire(x):
    return pickle.loads(pickle.dumps(x))


def test_segment_roundtrip_bitexact():
    seg = _seg(
        job_id=7, cids=(3, 1), degree=2, start_steps=(5, 0), run_steps=11,
        done=(1,), preempted=True, units=(4, 5), start=1.25, end=9.75,
    )
    assert decode_segment(_wire(encode_segment(seg))) == seg


def test_tree_roundtrip_bitexact():
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    tree = {
        "w": {"a": rng.randn(3, 4).astype(np.float32),
              "b": jnp.arange(6, dtype=jnp.int32)},
        "m": rng.randn(2, 2),  # float64 stays float64
    }
    out = _wire(encode_tree(tree))
    assert isinstance(out["w"]["b"], np.ndarray)
    for got, want in (
        (out["w"]["a"], tree["w"]["a"]),
        (out["w"]["b"], np.asarray(tree["w"]["b"])),
        (out["m"], tree["m"]),
    ):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_record_roundtrip():
    rec = JobRecord(
        ScheduledJob((2, 0), 2, 0.5, 3.5), 1.25,
        np.asarray([1.5, 2.5], np.float32),
    )
    out = decode_record(_wire(encode_record(rec)))
    assert out.job == rec.job and out.wall_seconds == rec.wall_seconds
    np.testing.assert_array_equal(out.final_losses, rec.final_losses)
    none = JobRecord(ScheduledJob((0,), 1, 0.0, 1.0), 0.0, None)
    assert decode_record(_wire(encode_record(none))).final_losses is None


def test_memory_pool_capture_contract():
    state = {"w": np.ones(2, np.float32)}
    mp_ = MemoryPool({"0003": (state, {"steps_done": 5})})
    assert mp_.has_adapter_state("0003") and not mp_.has_adapter_state("0001")
    tree, meta = mp_.load_adapter_state("0003")
    assert meta["steps_done"] == 5
    mp_.save_adapter("adapter_0003", {"w": np.zeros(2)}, {"final_loss": 1.0})
    mp_.save_adapter_state("0004", state, {"steps_done": 2})
    kinds = [w.kind for w in mp_.writes]
    assert kinds == ["adapter", "state"]
    assert [w.adapter_id for w in mp_.writes] == ["adapter_0003", "0004"]


# ---------------------------------------------------------------------------
# Dispatcher semantics over in-memory fake transports (no subprocesses)
# ---------------------------------------------------------------------------


def _fake_factory(made, kwargs_by_index=None):
    """Transport factory that records every instantiation; per-instantiation
    kwargs come from ``kwargs_by_index`` (key = 0-based creation index)."""
    kwargs_by_index = kwargs_by_index or {}

    def factory(host_id, n_devices):
        tr = FakeHostTransport(
            host_id, n_devices, **kwargs_by_index.get(len(made), {})
        )
        made.append(tr)
        return tr

    return factory


def test_dispatch_across_hosts_translates_units_and_applies_writes():
    made = []
    cfgs = {i: _cfg(alpha=8.0 * (i + 1)) for i in range(4)}
    segs = [_seg(job_id=i, cids=(i,), units=(i,)) for i in range(4)]
    pool = DictPool()
    with HostDispatcher([2, 2], transport_factory=_fake_factory(made)) as disp:
        result = disp.run(
            segs, cfgs, {i: 3 for i in range(4)}, None, None,
            seq=SEQ, pool=pool,
        )
    assert len(result.records) == 4
    assert disp.n_restarts == 0
    # two workers, two segments each, with units translated host-locally
    assert sorted(tr.host_id for tr in made) == [0, 1]
    for tr in made:
        assert len(tr.runs) == 2
        assert sorted(r["units"] for r in tr.runs) == [(0,), (1,)]
    # checkpoint traffic flowed back through the message protocol
    assert sorted(pool.adapters) == [f"adapter_{i:04d}" for i in range(4)]


def test_dispatch_resume_ships_state_over_the_wire():
    made = []
    cfgs = {0: _cfg()}
    segs = [
        _seg(job_id=0, run_steps=2, done=(), preempted=True, units=(0,)),
        _seg(job_id=1, start_steps=(2,), run_steps=3, units=(0,), start=1.0),
    ]
    pool = DictPool()
    with HostDispatcher([1], transport_factory=_fake_factory(made)) as disp:
        disp.run(segs, cfgs, {0: 5}, None, None, seq=SEQ, pool=pool)
    (tr,) = made
    # the preempted segment's state write landed in the central pool, and
    # the resume segment received it over the wire (FakeHostTransport
    # asserts steps_done == start_steps)
    assert tr.resumed == [(1, "0000")]
    assert pool.adapters and pool.states["0000"][1]["steps_done"] == 2


def test_killed_worker_requeues_residual_through_preempt_path():
    """Worker death mid-(resumed)-segment: the dispatcher respawns the host
    and re-dispatches the same residual — resumed from unchanged pool state,
    with nothing double-applied (writes are success-atomic)."""
    made = []
    cfgs = {0: _cfg()}
    segs = [
        _seg(job_id=0, run_steps=2, done=(), preempted=True, units=(0,)),
        _seg(job_id=1, start_steps=(2,), run_steps=3, units=(0,), start=1.0),
    ]
    pool = DictPool()
    factory = _fake_factory(made, {0: {"die_on": lambda idx, payload: idx == 1}})
    with HostDispatcher([1], transport_factory=factory) as disp:
        result = disp.run(segs, cfgs, {0: 5}, None, None, seq=SEQ, pool=pool)
    assert disp.n_restarts == 1
    assert len(made) == 2  # original + respawn
    # the respawned worker got the SAME residual segment, resumed at step 2
    retry = made[1].runs[0]
    assert retry["seg"].start_steps == (2,)
    assert retry["seg"].run_steps == 3
    assert made[1].resumed == [(0, "0000")]
    assert len(result.records) == 2
    assert sorted(pool.adapters) == ["adapter_0000"]


def test_worker_dying_forever_raises_not_hangs():
    made = []
    factory = _fake_factory(
        made, {i: {"die_on": lambda idx, payload: True} for i in range(5)}
    )
    with HostDispatcher(
        [1], transport_factory=factory, max_restarts=1
    ) as disp:
        with pytest.raises(WorkerDied, match="died 2 times"):
            disp.run(
                [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
                seq=SEQ, pool=DictPool(),
            )
    assert len(made) == 2  # initial + one restart


def test_kernel_policy_ships_to_workers():
    """`impl`/`remat` ride the wire as a typed KernelPolicy with every
    segment (previously multi-host loudly rejected non-default policy)."""
    from repro.cluster.multihost import KernelPolicy

    made = []
    segs = [_seg(job_id=i, cids=(i,), units=(0,), start=float(i))
            for i in range(2)]
    with HostDispatcher([1], transport_factory=_fake_factory(made)) as disp:
        disp.run(
            segs, {i: _cfg() for i in range(2)}, {i: 3 for i in range(2)},
            None, None, seq=SEQ, pool=DictPool(),
            impl="fused_xla", remat="recompute",
        )
    (tr,) = made
    assert tr.policies == [KernelPolicy("fused_xla", "recompute")] * 2


def test_kernel_policy_defaults_to_context(monkeypatch):
    """With no explicit impl, the caller's context-local default is captured
    and shipped ("auto" normalizes to None = worker default)."""
    from repro.cluster.multihost import KernelPolicy
    from repro.kernels.ops import use_impl

    made = []
    with HostDispatcher([1], transport_factory=_fake_factory(made)) as disp:
        with use_impl("fused"):
            disp.run(
                [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
                seq=SEQ, pool=DictPool(),
            )
        disp.run(
            [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
            seq=SEQ, pool=DictPool(),
        )
    (tr,) = made
    assert tr.policies[0] == KernelPolicy("fused", None)
    assert tr.policies[1] == KernelPolicy(None, None)  # "auto" -> None


def test_payload_reinit_on_new_workload():
    """Regression: the init-payload memo keys on *values*, not object ids —
    a second workload with different configs re-initializes the workers,
    while a content-identical one (fresh dict objects) does not."""
    made = []
    segs = [_seg(units=(0,))]
    with HostDispatcher([1], transport_factory=_fake_factory(made)) as disp:
        disp.run(segs, {0: _cfg()}, {0: 3}, None, None, seq=SEQ,
                 pool=DictPool())
        v1 = disp._payload_version
        disp.run(segs, {0: _cfg()}, {0: 3}, None, None, seq=SEQ,
                 pool=DictPool())
        assert disp._payload_version == v1  # same values: no re-init
        disp.run(segs, {0: _cfg(rank=16, alpha=16.0)}, {0: 3}, None, None,
                 seq=SEQ, pool=DictPool())
        assert disp._payload_version == v1 + 1  # new workload: re-init


def test_host_spanning_slice_rejected():
    made = []
    with HostDispatcher([2, 2], transport_factory=_fake_factory(made)) as disp:
        with pytest.raises(RuntimeError, match="span hosts"):
            disp.run(
                [_seg(degree=2, units=(1, 2), run_steps=1)],
                {0: _cfg()}, {0: 1}, None, None, seq=SEQ, pool=DictPool(),
            )


def test_adaptive_engine_runs_over_dispatch_tier():
    """run_online_local's adaptive loop (probe -> checkpoint -> resume) runs
    unchanged over the dispatcher: probes round-trip their state through the
    message protocol and every budget lands exactly."""
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import Arrival, ExecutionEngine
    from repro.sched.profile import ProfiledCostModel

    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    prior.setup_time = 0.0
    est = ProfiledCostModel(prior, drift_threshold=0.5)
    made = []
    with HostDispatcher([1], transport_factory=_fake_factory(made)) as disp:
        eng = ExecutionEngine(est, 1, host_size=1)
        records, sched = eng.run_online_local(
            [Arrival(0.0, _cfg(), 12)],
            reduced(get_config("qwen25-7b")),
            None,
            n_steps=12,
            seq=SEQ,
            pool=DictPool(),
            runner=disp,
            probe_steps=4,
        )
    assert sched.n_probes == 1
    executed = sum(
        min(sched.total_steps[cid] - s.start_steps[i], s.run_steps)
        for s in sched.segments
        for i, cid in enumerate(s.config_ids)
    )
    assert executed == 12
    assert sorted(sched.completed) == [0]


# ---------------------------------------------------------------------------
# Heartbeats, elastic membership, graceful drain (ISSUE 10). CI's chaos smoke
# runs exactly this section: pytest -k "elastic or drain or heartbeat".
# ---------------------------------------------------------------------------


def _state_spy(disp):
    """Record every membership transition deterministically (a sampler thread
    could miss a short-lived state)."""
    seen = []
    orig = disp._set_host_state

    def spy(host, state, **why):
        seen.append((host, state, why.get("reason")))
        orig(host, state, **why)

    disp._set_host_state = spy
    return seen


def test_heartbeat_pongs_keep_host_alive():
    from repro.obs import Tracer

    made = []
    tracer = Tracer()
    with HostDispatcher(
        [1], transport_factory=_fake_factory(made), tracer=tracer,
        heartbeat_interval=0.02,
    ) as disp:
        disp.run(
            [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
            seq=SEQ, pool=DictPool(),
        )
        deadline = time.perf_counter() + 2.0
        while made[0].pings < 3 and time.perf_counter() < deadline:
            time.sleep(0.01)
    assert made[0].pings >= 3
    assert disp.host_state(0) == "ALIVE"
    assert disp.hosts_alive == 1
    assert disp.n_restarts == 0
    rtt = tracer.metrics.histogram("cluster.heartbeat_rtt").summary()
    assert rtt["count"] >= 3 and rtt["max"] < 2.0


def test_heartbeat_detects_hung_worker_and_recovers():
    """A worker that wedges mid-segment (silent, but the process stays alive
    — only silence distinguishes it) must not hang run(): the watchdog walks
    it ALIVE -> SUSPECT -> DEAD, fails the in-flight segment, and the normal
    restart path re-runs it on a fresh worker."""
    made = []
    factory = _fake_factory(
        made, {0: {"hang_on": lambda idx, payload: idx == 0}}
    )
    with HostDispatcher(
        [1], transport_factory=factory,
        heartbeat_interval=0.02, heartbeat_timeout=0.04,
        heartbeat_dead_after=2,
    ) as disp:
        seen = _state_spy(disp)
        result = disp.run(
            [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
            seq=SEQ, pool=DictPool(),
        )
    assert len(result.records) == 1
    assert len(made) == 2  # hung original + respawn
    assert made[0].error is None  # it wedged; it did not crash
    assert disp.n_restarts == 1  # died with a segment in flight
    # the *heartbeat* made the call (the pump alone cannot: the process
    # stayed alive until the watchdog killed it)
    reasons = {r for _, _, r in seen}
    assert {"heartbeat_timeout", "heartbeat_expired"} <= reasons
    states = [(h, s) for h, s, _ in seen]
    assert (0, "SUSPECT") in states and (0, "DEAD") in states
    assert states.index((0, "SUSPECT")) < states.index((0, "DEAD"))
    assert disp.host_state(0) == "ALIVE"  # respawn rejoined the fleet


def test_heartbeat_pong_recovers_suspect_host():
    """One late pong un-suspects a host (misses reset; no restart burned)."""
    from repro.cluster.multihost import HealthReply

    made = []
    with HostDispatcher(
        [1], transport_factory=_fake_factory(made)
    ) as disp:
        disp.run(
            [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
            seq=SEQ, pool=DictPool(),
        )
        disp._set_host_state(0, "SUSPECT", reason="test")
        disp._hb_misses[0] = 2
        disp._on_pong(0, HealthReply(
            host=0, seq=7, t_send=time.perf_counter(), in_flight=0,
        ))
        assert disp.host_state(0) == "ALIVE"
        assert disp._hb_misses[0] == 0
    assert disp.n_restarts == 0


def test_heartbeat_idle_death_burns_no_restart_credit():
    """Regression (the idle-death accounting bug): a worker dying *between*
    segments — spot reclaim while idle — must not burn a ``max_restarts``
    credit; only in-flight deaths do (see
    test_killed_worker_requeues_residual_through_preempt_path, which pins
    the in-flight counterpart at n_restarts == 1)."""
    made = []
    with HostDispatcher(
        [1], transport_factory=_fake_factory(made), max_restarts=0
    ) as disp:
        disp.run(
            [_seg(units=(0,))], {0: _cfg()}, {0: 3}, None, None,
            seq=SEQ, pool=DictPool(),
        )
        disp.kill_host(0)  # idle: nothing in flight
        deadline = time.perf_counter() + 5.0
        while not disp._workers[0].dead and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert disp._workers[0].dead
        # with max_restarts=0 an (incorrectly) burned credit would raise
        # WorkerDied here instead of respawning
        result = disp.run(
            [_seg(job_id=1, units=(0,), start=1.0)], {0: _cfg()}, {0: 3},
            None, None, seq=SEQ, pool=DictPool(),
        )
    assert disp.n_restarts == 0
    assert len(made) == 2  # respawned, just not *charged*
    assert len(result.records) == 1


def _adaptive_over(disp, arrivals, *, pool=None, probe_steps=4):
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.profile import ProfiledCostModel

    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    prior.setup_time = 0.0
    est = ProfiledCostModel(prior, drift_threshold=0.5)
    eng = ExecutionEngine(est, disp.total_units, host_size=1)
    return eng.run_online_local(
        arrivals,
        reduced(get_config("qwen25-7b")),
        None,
        n_steps=max(a.steps for a in arrivals),
        seq=SEQ,
        pool=pool if pool is not None else DictPool(),
        runner=disp,
        probe_steps=probe_steps,
    )


def _executed_steps(sched):
    return sum(
        min(sched.total_steps[cid] - s.start_steps[i], s.run_steps)
        for s in sched.segments
        for i, cid in enumerate(s.config_ids)
    )


def test_elastic_join_mid_run_engine_replans_onto_new_host():
    """add_host() mid-run: the engine learns of the join through the
    membership feed and replans onto the new host's units — both jobs
    finish their exact budgets, and the joiner really ran work."""
    from repro.sched.engine import Arrival

    made = []
    box = {}
    joined = []

    def on_run(idx, payload):
        if idx == 0 and not joined:  # first segment lands -> a host joins
            joined.append(box["disp"].add_host(1, host_class="fast"))

    def factory(host_id, n_devices):
        tr = FakeHostTransport(
            host_id, n_devices, real_time=True, iter_scale=0.02,
            on_run=on_run if host_id == 0 else None,
        )
        made.append(tr)
        return tr

    with HostDispatcher([1], transport_factory=factory) as disp:
        box["disp"] = disp
        # staggered so the jobs can't pack into one segment: the second
        # arrives while the first trains, after the join — with host 0 busy
        # the only place for it is the joiner
        arrivals = [Arrival(0.0, _cfg(), 12),
                    Arrival(0.05, _cfg(alpha=16.0), 12)]
        records, sched = _adaptive_over(disp, arrivals)
    assert joined == [1]
    assert disp.total_units == 2
    assert disp.host_classes == ("", "fast")
    assert sorted(sched.completed) == [0, 1]
    assert _executed_steps(sched) == 24
    by_host = {tr.host_id for tr in made if tr.runs}
    assert by_host == {0, 1}  # the joiner actually executed segments


def test_graceful_drain_loses_zero_steps():
    """drain_host() mid-run: in-flight work finishes (checkpoints land
    through the normal success-atomic path), the residual migrates to the
    surviving host at the exact step count, and the drained host's units
    retire from the pool — zero steps lost, zero double-run."""
    from repro.sched.engine import Arrival

    made = []
    box = {}
    threads = []

    def on_run(idx, payload):
        if idx == 0 and not threads:  # host 1's first segment is in flight
            t = threading.Thread(
                target=lambda: box["disp"].drain_host(1, timeout=30)
            )
            t.start()
            threads.append(t)

    def factory(host_id, n_devices):
        tr = FakeHostTransport(
            host_id, n_devices, real_time=True, iter_scale=0.02,
            on_run=on_run if host_id == 1 else None,
        )
        made.append(tr)
        return tr

    pool = DictPool()
    with HostDispatcher([1, 1], transport_factory=factory) as disp:
        box["disp"] = disp
        # staggered so the jobs can't pack into one segment: the second
        # lands on host 1 (host 0 is busy) and is the one drained mid-run
        arrivals = [Arrival(0.0, _cfg(), 12),
                    Arrival(0.05, _cfg(alpha=16.0), 12)]
        records, sched = _adaptive_over(disp, arrivals, pool=pool)
        for t in threads:
            t.join(timeout=30)
    assert threads and not threads[0].is_alive()  # drain completed
    assert disp.host_state(1) == "DEAD"
    assert disp.device_pool.retired == (1,)
    assert sorted(sched.completed) == [0, 1]
    assert _executed_steps(sched) == 24  # nothing lost, nothing doubled
    tr1 = next(tr for tr in made if tr.host_id == 1)
    assert len(tr1.runs) == 1  # nothing dispatched after the drain announce
    # the drained host's job resumed elsewhere from its checkpointed steps
    resumed_on_0 = [
        aid for tr in made if tr.host_id == 0 for _, aid in tr.resumed
    ]
    assert "0001" in resumed_on_0
    assert sorted(pool.adapters) == ["adapter_0000", "adapter_0001"]


def test_drain_mid_death_checkpoint_writes_atomic():
    """Satellite: a host killed *mid-drain* (segment in flight) must leave
    the pool atomic — the killed attempt's writes never half-apply, and the
    residual re-enters at the pre-drain step count (the respawned worker's
    shipped state is asserted by the fake)."""
    from repro.sched.engine import Arrival

    made = []
    box = {}

    def die1(idx, payload):
        if idx != 1:
            return False
        # the resumed continuation (start_steps=4) is in flight: start the
        # drain, let the announce land, then die silently (SIGKILL)
        t = threading.Thread(
            target=lambda: box["disp"].drain_host(0, timeout=60)
        )
        t.start()
        box["drain"] = t
        time.sleep(0.05)
        return True

    def factory(host_id, n_devices):
        tr = FakeHostTransport(host_id, n_devices, die_on=die1)
        made.append(tr)
        return tr

    pool = DictPool()
    with HostDispatcher([1], transport_factory=factory) as disp:
        box["disp"] = disp
        records, sched = _adaptive_over(
            disp, [Arrival(0.0, _cfg(), 12)], pool=pool
        )
        box["drain"].join(timeout=60)
    assert not box["drain"].is_alive()
    assert disp.host_state(0) == "DEAD"
    assert disp.n_restarts == 1  # the mid-drain kill was in flight
    assert len(made) == 2
    # atomicity: the killed attempt applied nothing — the retry resumed
    # from the probe checkpoint (steps_done == 4), not a torn write
    assert pool.states["0000"][1]["steps_done"] == 4
    retry = made[1].runs[0]
    assert retry["seg"].start_steps == (4,)
    assert made[1].resumed == [(0, "0000")]
    assert _executed_steps(sched) == 12
    assert sorted(pool.adapters) == ["adapter_0000"]


def test_elastic_pool_add_and_retire_units():
    from repro.cluster.pool import DevicePool

    p = DevicePool(devices=["d0", "d1"])
    assert p.add_devices(["d2", "d3"]) == (2, 3)
    assert p.total == 4 and p.free == 4
    s = p.acquire_units([1])
    # retire blocks until the unit is free, then removes it for good
    done = threading.Event()

    def retire():
        p.retire_units([1], timeout=5.0)
        done.set()

    t = threading.Thread(target=retire)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()  # still busy -> retire waits
    p.release(s)
    t.join(timeout=5)
    assert done.is_set() and p.retired == (1,)
    with pytest.raises(RuntimeError, match="retired"):
        p.acquire_units([1])
    assert p.acquire_units([0, 2, 3]).units == (0, 2, 3)


def test_elastic_class_aware_unit_pick():
    """pick_class_units: wide jobs go to the fastest class, narrow jobs to
    the slowest (keeping fast hosts open), SUSPECT hosts are last resort."""
    from repro.cluster.pool import pick_class_units

    classes = {0: "fast", 1: "fast", 2: "slow"}
    ratios = {"fast": 1.0, "slow": 4.0}
    kw = dict(
        class_of_host=lambda h: classes[h],
        ratio_of_class=lambda c: ratios[c],
    )
    free = [0, 1, 2, 3, 4, 5]  # hosts 0..2, 2 units each
    assert pick_class_units(free, 2, 2, **kw) == (0, 1)  # wide -> fast
    assert pick_class_units(free, 1, 2, **kw) == (4,)    # narrow -> slow
    # suspect fast host: wide work flees to the healthy fast host
    assert pick_class_units(
        free, 2, 2, avoid_host=lambda h: h == 0, **kw
    ) == (2, 3)
    assert pick_class_units([0], 2, 2, **kw) is None  # nothing fits


# ---------------------------------------------------------------------------
# Real subprocesses (CPU-forced workers; CI's multihost matrix entry)
# ---------------------------------------------------------------------------


def _grid4():
    return [
        _cfg(rank=8, alpha=8.0, lr=1e-3),
        _cfg(rank=8, alpha=16.0, lr=5e-4),
        _cfg(rank=16, alpha=16.0, lr=1e-3),
        _cfg(rank=16, alpha=32.0, lr=2e-4),
    ]


def _run_schedule(disp, host_size, grid, cfg, base, n_steps=3):
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.planner import Schedule

    g = disp.total_units
    jobs = [ScheduledJob((i,), 1, 0.0, 1.0) for i in range(len(grid))]
    eng = ExecutionEngine(CostModel(cfg, A100_40G), g, host_size=host_size)
    records, makespan = eng.run_local(
        Schedule(jobs, 1.0, g), grid, cfg, base, n_steps=n_steps, seq=SEQ,
        runner=disp,
    )
    by_cid = {r.job.config_ids[0]: r.final_losses for r in records}
    return np.concatenate([by_cid[i] for i in range(len(grid))])


@pytest.mark.slow
def test_two_hosts_bitexact_vs_single_host_subprocess():
    """Acceptance: the 4-group schedule on 2 hosts x 4 devices produces
    per-adapter losses bit-identical to the 1-host 8-device run."""
    import jax

    from repro.core.adapter import pack_meta
    from repro.models.model import init_model

    cfg = reduced(get_config("qwen25-7b"))
    grid = _grid4()
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    with HostDispatcher([8]) as disp1:
        ref = _run_schedule(disp1, 8, grid, cfg, base)
    with HostDispatcher([4, 4]) as disp2:
        out = _run_schedule(disp2, 4, grid, cfg, base)
    assert np.isfinite(ref).all()
    np.testing.assert_array_equal(ref, out)
    assert disp2.last_result.max_overlap() >= 2  # hosts really overlapped


@pytest.mark.slow
def test_killed_subprocess_worker_recovers_bitexact(tmp_path):
    """Acceptance: SIGKILL a real HostWorker mid-segment — the run completes
    (no hang), every adapter's exact step budget is honored, and losses are
    bit-identical to an unkilled in-process reference."""
    import jax

    from repro.cluster import ClusterRunner, DevicePool, SliceExecutor
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.planner import Schedule
    from repro.train.checkpoint import CheckpointPool

    cfg = reduced(get_config("qwen25-7b"))
    grid = [_cfg()]
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    cm = CostModel(cfg, A100_40G)
    jobs = [ScheduledJob((0,), 1, 0.0, 1.0)]
    n_steps = 6

    # unkilled in-process reference (also proves dispatch == in-process)
    eng = ExecutionEngine(cm, 1)
    runner = ClusterRunner(
        SliceExecutor(), DevicePool(jax.devices()[:1]), concurrent=False
    )
    recs, _ = eng.run_local(
        Schedule(jobs, 1.0, 1), grid, cfg, base, n_steps=n_steps, seq=SEQ,
        runner=runner,
    )
    ref = np.concatenate([r.final_losses for r in recs])

    eng_mh = ExecutionEngine(cm, 1, host_size=1)
    # the killed segment is the first on a fresh (cold) worker, so the
    # in-flight window is many seconds wide (spawn + jax init + compile);
    # the retry loop still guards the theoretical completed-before-kill race
    for attempt in range(2):
        pool = CheckpointPool(str(tmp_path / f"pool{attempt}"))
        with HostDispatcher([1]) as disp:
            stop = threading.Event()

            def killer():
                while not stop.is_set():
                    if disp.in_flight(0) > 0:
                        time.sleep(1.5)  # land mid-compile / mid-steps
                        if disp.in_flight(0) > 0 and not stop.is_set():
                            disp.kill_host(0)
                        return
                    time.sleep(0.02)

            th = threading.Thread(target=killer)
            th.start()
            try:
                recs_mh, _ = eng_mh.run_local(
                    Schedule(jobs, 1.0, 1), grid, cfg, base, n_steps=n_steps,
                    seq=SEQ, pool=pool, runner=disp,
                )
            finally:
                stop.set()
                th.join()
        out = np.concatenate([r.final_losses for r in recs_mh])
        np.testing.assert_array_equal(ref, out)  # holds killed or not
        if disp.n_restarts >= 1:
            break  # the kill landed mid-segment and was recovered
    assert disp.n_restarts >= 1
    meta = pool.load_meta("adapter_0000")
    assert meta["total_steps"] == n_steps
    assert np.isfinite(meta["final_loss"])

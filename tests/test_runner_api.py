"""Runner protocol conformance (ISSUE 6 satellite).

One parametrized test drives every :class:`repro.cluster.api.Runner`
implementation — ``ClusterRunner`` over fakes, ``HostDispatcher`` over the
in-memory ``FakeHostTransport``, ``ServeEngine`` delegating training to its
inner runner, and the harness ``FakeRunner`` — through the same segment
batch and asserts the shared semantics: surface (``isinstance`` against the
runtime-checkable protocol), records in virtual-start order, and the pool
draining back to its entry free count.
"""
import jax
import numpy as np
import pytest
from harness import DictPool, FakeHostTransport, FakeRunner, ScriptedExecutor, fake_pool

from repro.cluster import ClusterRunner, HostDispatcher, Runner
from repro.configs.base import LoraConfig, get_config, reduced
from repro.models.model import init_model
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import JobSegment
from repro.serve.engine import ServeEngine

SEQ = 16


def _cfgs(n):
    return {
        i: LoraConfig(rank=8, alpha=8.0 * (i + 1), learning_rate=1e-3,
                      batch_size=1, seq_len=SEQ)
        for i in range(n)
    }


def _segs(n):
    return [
        JobSegment(
            job_id=i, config_ids=(i,), degree=1, start=float(i),
            end=i + 1.0, start_steps=(0,), run_steps=2, done_ids=(i,),
            units=(0,),
        )
        for i in range(n)
    ]


def _prior():
    return CostModel(get_config("qwen25-7b"), A100_40G)


def _cluster_runner():
    runner = ClusterRunner(ScriptedExecutor(_prior()), fake_pool(2))
    return runner, (lambda: None)


def _fake_runner():
    return FakeRunner(ScriptedExecutor(_prior()), 2), (lambda: None)


def _host_dispatcher():
    made = []

    def factory(host_id, n_devices):
        tr = FakeHostTransport(host_id, n_devices)
        made.append(tr)
        return tr

    disp = HostDispatcher([2], transport_factory=factory)
    return disp, disp.close


_SERVE_STATE = {}


def _serve_engine():
    # init_model is the expensive part; share one across parametrizations
    if "init" not in _SERVE_STATE:
        cfg = reduced(get_config("gemma3-1b"))
        base, _ = init_model(jax.random.PRNGKey(0), cfg, None)
        _SERVE_STATE["init"] = (cfg, base)
    cfg, base = _SERVE_STATE["init"]
    eng = ServeEngine(
        cfg, base, rows=1, smax=16, train_executor=ScriptedExecutor(_prior()),
        device_pool=fake_pool(2),
    )
    return eng, (lambda: None)


IMPLS = {
    "cluster_runner": _cluster_runner,
    "fake_runner": _fake_runner,
    "host_dispatcher": _host_dispatcher,
    "serve_engine": _serve_engine,
}


@pytest.mark.parametrize("name", sorted(IMPLS))
def test_runner_conformance(name):
    runner, close = IMPLS[name]()
    try:
        assert isinstance(runner, Runner), name
        assert hasattr(runner.executor, "run_segment")
        free0 = runner.device_pool.free
        n = 3
        result = runner.run(
            _segs(n), _cfgs(n), {i: 2 for i in range(n)}, None, None,
            seq=SEQ, pool=DictPool() if name == "host_dispatcher" else None,
        )
        assert len(result.records) == n
        # records in virtual-start order, each for its own segment
        assert [tuple(r.job.config_ids) for r in result.records] == [
            (i,) for i in range(n)
        ]
        assert result.makespan >= 0.0
        # the pool drained back to its entry free count
        assert runner.device_pool.free == free0
    finally:
        close()


def test_serve_engine_run_respects_foreign_lease():
    """Training through ServeEngine.run while the decode side holds its
    serve lease: the runner must not treat the held unit as leaked."""
    eng, _ = _serve_engine()
    with eng.serve_lease(1):
        free0 = eng.device_pool.free
        assert free0 == eng.device_pool.total - 1
        result = eng.run(
            _segs(2), _cfgs(2), {i: 2 for i in range(2)}, None, None,
            seq=SEQ,
        )
        assert len(result.records) == 2
        assert eng.device_pool.free == free0  # lease still held, no leak
    assert eng.device_pool.free == eng.device_pool.total


def test_kernel_policy_reaches_executor_through_any_runner():
    """impl crosses every runner's thread/process boundary explicitly."""
    for factory in (_cluster_runner, _fake_runner):
        runner, _ = factory()
        runner.run(
            _segs(1), _cfgs(1), {0: 2}, None, None, seq=SEQ,
            impl="fused_xla",
        )
        assert runner.executor.impls == ["fused_xla"]

"""Attention primitives: chunked flash vs naive, sliding window banding,
GQA grouping, RoPE invariants, MLA absorbed-decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import (skips sans hypothesis)

from repro.configs.base import AttentionConfig
from repro.models.layers.attention import (
    _attend_chunk,
    apply_gqa,
    apply_mla,
    decode_attention,
    flash_attention,
    init_gqa,
    init_gqa_cache,
    init_mla,
    init_mla_cache,
)
from repro.models.layers.rope import apply_rope, rope_tables


def _naive_attention(q, k, v, causal=True, window=0, scale=None):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else d**-0.5
    qg = q.reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, -1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, sq, h, d)


@pytest.mark.parametrize("sq,chunk", [(8, 32), (32, 8), (64, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(sq, chunk, causal):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    b, h, kv, d = 2, 4, 2, 16
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sq, kv, d))
    v = jax.random.normal(ks[2], (b, sq, kv, d))
    got = flash_attention(q, k, v, causal=causal, chunk_q=chunk)
    want = _naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [4, 16, 31])
def test_sliding_window_band_path(window):
    """The banded (sub-quadratic) path == naive masked attention."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    b, sq, h, d = 1, 64, 2, 8
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sq, h, d))
    v = jax.random.normal(ks[2], (b, sq, h, d))
    got = flash_attention(q, k, v, causal=True, window=window, chunk_q=16)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    sq=st.sampled_from([16, 32, 48]),
    window=st.integers(1, 40),
    chunk=st.sampled_from([8, 16]),
)
def test_window_property(sq, window, chunk):
    key = jax.random.PRNGKey(sq * 100 + window)
    q = jax.random.normal(key, (1, sq, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, sq, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, sq, 2, 8))
    got = flash_attention(q, k, v, causal=True, window=window, chunk_q=chunk)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("sq", [100, 1500, 33])
def test_flash_q_padding_non_divisible(sq):
    """sq not divisible by chunk_q (e.g. whisper's 1500 frames): padded query
    chunks must not change real outputs."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, sq, 2, 8))
    k = jax.random.normal(ks[1], (1, sq, 2, 8))
    v = jax.random.normal(ks[2], (1, sq, 2, 8))
    got = flash_attention(q, k, v, causal=True, chunk_q=32)
    want = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    # non-causal too (whisper encoder)
    got_nc = flash_attention(q, k, v, causal=False, chunk_q=32)
    want_nc = _naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got_nc), np.asarray(want_nc), rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_full():
    """decode at position p == row p of the full causal attention."""
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    b, s, h, kv, d = 2, 16, 4, 2, 8
    q_full = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    full = _naive_attention(q_full, k, v, causal=True)
    for p in (0, 7, 15):
        got = decode_attention(q_full[:, p : p + 1], k, v, jnp.int32(p))
        np.testing.assert_allclose(
            np.asarray(got[:, 0]), np.asarray(full[:, p]), rtol=1e-4, atol=1e-4
        )


def test_rope_preserves_norm_and_relative_scores():
    pos = jnp.arange(16)
    cos, sin = rope_tables(pos, 8, 10_000.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 2, 8))
    y = apply_rope(x, cos, sin)
    # rotation preserves per-pair norms
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(4), (8,))
    k = jax.random.normal(jax.random.PRNGKey(5), (8,))

    def score(i, j):
        ci, si = rope_tables(jnp.asarray([i]), 8, 10_000.0)
        cj, sj = rope_tables(jnp.asarray([j]), 8, 10_000.0)
        qr = apply_rope(q[None, None, None, :], ci, si)
        kr = apply_rope(k[None, None, None, :], cj, sj)
        return float((qr * kr).sum())

    np.testing.assert_allclose(score(3, 1), score(10, 8), rtol=1e-4)
    np.testing.assert_allclose(score(5, 5), score(12, 12), rtol=1e-4)


def test_gqa_cache_decode_matches_prefill(meta2):
    acfg = AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16)
    d_model = 32
    params, lora = init_gqa(jax.random.PRNGKey(0), acfg, d_model, meta2, ("q", "k", "v", "o"))
    nb, s = meta2.n, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (nb, s, d_model)) * 0.3
    pos = jnp.arange(s)
    rope = rope_tables(pos, acfg.head_dim, acfg.rope_theta)
    scales = meta2.scales()
    full, _ = apply_gqa(
        params, lora, scales, x, acfg=acfg, n_pack=meta2.n, rope=rope
    )
    cache = init_gqa_cache(nb, s, acfg, jnp.float32)
    outs = []
    for t in range(s):
        r_t = rope_tables(jnp.asarray([t]), acfg.head_dim, acfg.rope_theta)
        o, cache = apply_gqa(
            params, lora, scales, x[:, t : t + 1], acfg=acfg, n_pack=meta2.n,
            rope=r_t, cache=cache, pos=jnp.int32(t),
        )
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=1e-3, atol=1e-3)


def test_mla_absorbed_decode_matches_prefill(meta2):
    acfg = AttentionConfig(
        n_heads=4, n_kv_heads=4, head_dim=32,
        q_lora_rank=24, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    )
    d_model = 32
    params, lora = init_mla(jax.random.PRNGKey(0), acfg, d_model, meta2, ("q", "kv", "o"))
    nb, s = meta2.n, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (nb, s, d_model)) * 0.3
    rope = rope_tables(jnp.arange(s), acfg.qk_rope_head_dim, 10_000.0)
    scales = meta2.scales()
    full, _ = apply_mla(params, lora, scales, x, acfg=acfg, n_pack=meta2.n, rope=rope)
    cache = init_mla_cache(nb, s, acfg, jnp.float32)
    outs = []
    for t in range(s):
        r_t = rope_tables(jnp.asarray([t]), acfg.qk_rope_head_dim, 10_000.0)
        o, cache = apply_mla(
            params, lora, scales, x[:, t : t + 1], acfg=acfg, n_pack=meta2.n,
            rope=r_t, cache=cache, pos=jnp.int32(t),
        )
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)

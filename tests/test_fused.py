"""Fused base+delta megakernel vs the two-pass reference.

Covers the acceptance matrix of the fused kernel tier: forward/gradient
equivalence across dtypes (f32/bf16), 3D and N-D pack layouts, heterogeneous
-rank packs (ragged segments), both remat policies (bit-identical), and the
``lora_linear`` dispatch (kcfg threading, bias ordering). The Pallas path
runs in interpret mode on CPU — the same kernel body that compiles for TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.packed_lora import lora_linear
from repro.kernels import ref
from repro.kernels.fused import fused_lora
from repro.kernels.ops import KernelConfig, fused_lora_linear, packed_lora_delta


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {
    jnp.float32: dict(rtol=1e-4, atol=1e-4),
    jnp.bfloat16: dict(rtol=2e-2, atol=2e-1),
}


def _setup(n, t, d, r, l, dtype=jnp.float32, lead=()):
    ks = jax.random.split(jax.random.PRNGKey(n * 100 + d), 4)
    x = _rand(ks[0], (n, *lead, t, d), dtype)
    w = _rand(ks[1], (d, l), dtype) * 0.1
    a = _rand(ks[2], (n, d, r), dtype) * 0.1
    b = _rand(ks[3], (n, r, l), dtype) * 0.1
    alpha = jnp.linspace(0.25, 2.0, n)
    return x, w, a, b, alpha


def _ref_out(x, w, a, b, alpha):
    return x @ w.astype(x.dtype) + jnp.einsum(
        "n...r,nrl->n...l",
        jnp.einsum("n...k,nkr->n...r", x, a.astype(x.dtype)),
        b.astype(x.dtype),
    ) * alpha.reshape(-1, *([1] * (x.ndim - 1))).astype(x.dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["fused_xla", "fused_pallas"])
def test_fused_forward_matches_ref(dtype, impl):
    x, w, a, b, alpha = _setup(3, 16, 40, 8, 36, dtype)
    got = fused_lora(x, w, a, b, alpha, impl=impl)
    want = _ref_out(x, w, a, b, alpha)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("impl", ["fused_xla", "fused_pallas"])
def test_fused_forward_nd_layout(impl):
    """N-D pack layout (N, B, S, d) — the FSDP execution-mode shape."""
    x, w, a, b, alpha = _setup(2, 8, 32, 8, 24, lead=(3,))
    got = fused_lora(x, w, a, b, alpha, impl=impl)
    want = _ref_out(x, w, a, b, alpha)
    assert got.shape == (2, 3, 8, 24)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("impl", ["fused_xla", "fused_pallas"])
def test_fused_grads_all_args(impl):
    """dx/dw/da/db against jax autodiff on the unfused reference — dx is the
    fused primitive again (transposed operands), so this exercises the
    g-tile-sharing backward too."""
    x, w, a, b, alpha = _setup(3, 12, 32, 8, 20)

    def f_fused(x, w, a, b):
        return (fused_lora(x, w, a, b, alpha, impl=impl) ** 2).sum()

    def f_ref(x, w, a, b):
        return (_ref_out(x, w, a, b, alpha) ** 2).sum()

    got = jax.grad(f_fused, argnums=(0, 1, 2, 3))(x, w, a, b)
    want = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w, a, b)
    for g, r, nm in zip(got, want, "xwab"):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=2e-4, atol=2e-4,
            err_msg=f"d{nm}",
        )


def test_fused_grads_nd_layout():
    x, w, a, b, alpha = _setup(2, 6, 24, 4, 16, lead=(2,))

    def f_fused(a, b):
        return (fused_lora(x, w, a, b, alpha, impl="fused_xla") ** 2).sum()

    def f_ref(a, b):
        return (_ref_out(x, w, a, b, alpha) ** 2).sum()

    got = jax.grad(f_fused, argnums=(0, 1))(a, b)
    want = jax.grad(f_ref, argnums=(0, 1))(a, b)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-4, atol=1e-4)


def test_remat_policies_bit_identical():
    """save-vs-recompute is a pure scheduling choice: same op on the same
    inputs, so values AND grads are bit-identical."""
    x, w, a, b, alpha = _setup(3, 16, 40, 8, 36)

    def grads(remat):
        return jax.grad(
            lambda a, b: (
                fused_lora(x, w, a, b, alpha, impl="fused_xla", remat=remat) ** 2
            ).sum(),
            argnums=(0, 1),
        )(a, b)

    ga_s, gb_s = grads("save")
    ga_r, gb_r = grads("recompute")
    assert (np.asarray(ga_s) == np.asarray(ga_r)).all()
    assert (np.asarray(gb_s) == np.asarray(gb_r)).all()
    # and for the two-pass delta as well — dB is the grad that actually
    # consumes the remat'd xA, so compare both
    da_s, db_s = jax.grad(
        lambda a, b: (packed_lora_delta(x, a, b, alpha, remat="save") ** 2).sum(),
        argnums=(0, 1),
    )(a, b)
    da_r, db_r = jax.grad(
        lambda a, b: (packed_lora_delta(x, a, b, alpha, remat="recompute") ** 2).sum(),
        argnums=(0, 1),
    )(a, b)
    assert (np.asarray(da_s) == np.asarray(da_r)).all()
    assert (np.asarray(db_s) == np.asarray(db_r)).all()


def test_fused_alpha_zero_cotangent():
    x, w, a, b, alpha = _setup(2, 8, 16, 4, 12)
    g = jax.grad(
        lambda al: fused_lora(x, w, a, b, al, impl="fused_xla").sum()
    )(alpha)
    np.testing.assert_allclose(np.asarray(g), 0.0)


# ---------------------------------------------------------------------------
# Heterogeneous ranks (ragged segments) through the fused path
# ---------------------------------------------------------------------------


def _het_pack(ranks, t=10, d=32, l=24):
    n = len(ranks)
    bucket = max(8, (max(ranks) + 7) // 8 * 8)
    x, w, a, b, alpha = _setup(n, t, d, bucket, l)
    mask_a = jnp.arange(bucket)[None, None, :] < jnp.asarray(ranks)[:, None, None]
    mask_b = jnp.arange(bucket)[None, :, None] < jnp.asarray(ranks)[:, None, None]
    return x, w, a * mask_a, b * mask_b, alpha, bucket


@pytest.mark.parametrize("ranks", [(4, 8, 2), (8, 16, 16, 8)])
@pytest.mark.parametrize("impl", ["fused_xla", "fused_pallas"])
def test_fused_ragged_matches_padded(ranks, impl):
    x, w, a, b, alpha, _ = _het_pack(ranks)
    padded = fused_lora_linear(x, w, a, b, alpha, impl=impl)
    ragged = fused_lora_linear(x, w, a, b, alpha, impl=impl, ranks=ranks)
    np.testing.assert_allclose(
        np.asarray(ragged), np.asarray(padded), rtol=1e-5, atol=1e-5
    )


def test_fused_ragged_grads_match_and_padding_grad_zero():
    ranks = (4, 8, 2)
    x, w, a, b, alpha, bucket = _het_pack(ranks)

    def loss(a, b, use_ranks):
        return (
            fused_lora_linear(
                x, w, a, b, alpha, impl="fused_xla",
                ranks=ranks if use_ranks else None,
            ) ** 2
        ).sum()

    ga_r, gb_r = jax.grad(lambda a, b: loss(a, b, True), argnums=(0, 1))(a, b)
    ga_p, gb_p = jax.grad(lambda a, b: loss(a, b, False), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_r), np.asarray(ga_p), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_r), np.asarray(gb_p), rtol=1e-4, atol=1e-4)
    # ragged segments never touch the padded region: its grad is bit-zero
    for i, r in enumerate(ranks):
        assert (np.asarray(ga_r)[i, :, r:] == 0.0).all()
        assert (np.asarray(gb_r)[i, r:, :] == 0.0).all()


# ---------------------------------------------------------------------------
# lora_linear dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bias", [False, True])
def test_lora_linear_fused_matches_two_pass(bias):
    n, bsz, t, d, l, r = 3, 2, 6, 32, 24, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = _rand(ks[0], (n * bsz, t, d), jnp.float32)
    params = {"w": _rand(ks[1], (d, l), jnp.float32) * 0.1}
    if bias:
        params["b"] = _rand(ks[4], (l,), jnp.float32) * 0.1
    lora = {
        "a": _rand(ks[2], (n, d, r), jnp.float32) * 0.1,
        "b": _rand(ks[3], (n, r, l), jnp.float32) * 0.1,
    }
    scales = jnp.asarray([0.5, 1.0, 2.0])
    two = lora_linear(x, params, lora, scales, n, kcfg=KernelConfig(impl="xla"))
    fus = lora_linear(x, params, lora, scales, n, kcfg=KernelConfig(impl="fused"))
    # bias ordering is the only reassociation (two-pass adds it before the
    # delta, fused after): allclose, and bit-equal without bias
    if bias:
        np.testing.assert_allclose(np.asarray(fus), np.asarray(two), rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_allclose(np.asarray(fus), np.asarray(two), rtol=1e-6, atol=1e-6)
    assert fus.shape == two.shape == (n * bsz, t, l)


def test_lora_linear_no_lora_ignores_fused():
    x = _rand(jax.random.PRNGKey(0), (4, 8, 16), jnp.float32)
    params = {"w": _rand(jax.random.PRNGKey(1), (16, 12), jnp.float32)}
    got = lora_linear(x, params, None, None, 2, kcfg=KernelConfig(impl="fused"))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x @ params["w"]))


# ---------------------------------------------------------------------------
# Property sweep
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 4),
    t=st.integers(1, 24),
    d=st.integers(1, 48),
    r=st.integers(1, 16),
    l=st.integers(1, 40),
)
def test_fused_xla_property(n, t, d, r, l):
    x, w, a, b, alpha = _setup(n, t, d, r, l)
    got = fused_lora(x, w, a, b, alpha, impl="fused_xla")
    want = _ref_out(x, w, a, b, alpha)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5
    )
